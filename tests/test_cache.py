"""Algorithm 1 behavior: compliance, thresholds, TTL, quotas, eviction, L1."""

import numpy as np
import pytest

from repro.core import SemanticCache, SimClock
from dataclasses import replace as dc_replace

from repro.core.embedding import make_dense_space, make_sparse_space


def tight(space):
    """Mixture-free variant: mechanics tests want deterministic hits."""
    return dc_replace(space, loose_frac=0.0)
from repro.core.hnsw import INVALID
from repro.core.policy import CategoryConfig, PolicyEngine


def make_cache(capacity=512, index_kind="flat", l1=0, policies=None):
    eng = policies or PolicyEngine([
        CategoryConfig("dense_cat", threshold=0.90, ttl=3600.0, quota=0.5,
                       priority=4.0),
        CategoryConfig("sparse_cat", threshold=0.75, ttl=600.0, quota=0.3),
        CategoryConfig("restricted", threshold=0.9, ttl=60.0, quota=0.1,
                       allow_caching=False),
    ])
    clock = SimClock()
    return SemanticCache(eng, capacity=capacity, clock=clock,
                         index_kind=index_kind, l1_capacity=l1), clock


def test_hit_on_paraphrase_miss_on_distinct_intent(rng):
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=3))
    for i in range(50):
        cache.insert(sp.sample(i, rng), "dense_cat", f"q{i}", f"r{i}")
    hits = sum(cache.lookup(sp.sample(i, rng), "dense_cat").hit
               for i in range(50))
    assert hits >= 45                       # paraphrases above τ=0.90
    miss = cache.lookup(sp.sample(1234, rng), "dense_cat")
    assert not miss.hit and miss.reason in ("no_match", "category_mismatch")


def test_compliance_never_stores_or_serves(rng):
    cache, _ = make_cache()
    emb = make_dense_space(seed=1).sample(0, rng)
    assert cache.insert(emb, "restricted", "q", "r") == INVALID
    assert len(cache) == 0                  # no temporary data presence
    res = cache.lookup(emb, "restricted")
    assert not res.hit and res.reason == "compliance"
    assert cache.metrics.cat("restricted").compliance_rejects >= 1


def test_ttl_validated_before_fetch(rng):
    cache, clock = make_cache()
    sp = tight(make_dense_space(seed=2))
    cache.insert(sp.sample(0, rng), "sparse_cat", "q", "r")
    assert cache.lookup(sp.sample(0, rng), "sparse_cat").hit
    clock.advance(601.0)                    # sparse_cat ttl = 600
    res = cache.lookup(sp.sample(0, rng), "sparse_cat")
    assert not res.hit and res.reason == "expired"
    # expired entry was evicted, not just skipped
    assert cache.metrics.cat("sparse_cat").ttl_evictions == 1
    assert len(cache) == 0


def test_per_category_thresholds_applied(rng):
    """Same geometric distance hits for the loose category only."""
    eng = PolicyEngine([
        CategoryConfig("tight", threshold=0.92, ttl=1e6, quota=0.5),
        CategoryConfig("loose", threshold=0.70, ttl=1e6, quota=0.5),
    ])
    cache, _ = make_cache(policies=eng)
    sp = make_sparse_space(seed=5)          # paraphrase cos ≈ 0.80
    rng2 = np.random.default_rng(7)
    # disjoint intents per category so top-1 stays within-category
    for i in range(20):
        cache.insert(sp.sample(i, rng2), "tight", f"q{i}", f"r{i}")
        cache.insert(sp.sample(100 + i, rng2), "loose", f"q{i}", f"r{i}")
    tight_hits = sum(cache.lookup(sp.sample(i, rng2), "tight").hit
                     for i in range(20))
    loose_hits = sum(cache.lookup(sp.sample(100 + i, rng2), "loose").hit
                     for i in range(20))
    assert loose_hits >= 15
    assert tight_hits <= 6


def test_quota_enforced_per_category(rng):
    cache, _ = make_cache(capacity=100)
    sp = make_dense_space(seed=4)
    for i in range(80):
        cache.insert(sp.sample(i, rng), "sparse_cat", f"q{i}", f"r{i}")
    # quota 0.3 × 100 = 30
    assert cache.category_count("sparse_cat") <= 30
    assert cache.metrics.cat("sparse_cat").quota_evictions > 0


def test_capacity_eviction_prefers_low_value(rng):
    cache, clock = make_cache(capacity=60)
    sp = make_dense_space(seed=6)
    # dense_cat has priority 4.0, sparse_cat 1.0
    for i in range(25):
        cache.insert(sp.sample(i, rng), "dense_cat", f"dq{i}", f"dr{i}")
    for i in range(25):
        cache.insert(sp.sample(1000 + i, rng), "sparse_cat", f"sq{i}", f"sr{i}")
    # hit the dense entries to raise their value
    for i in range(25):
        cache.lookup(sp.sample(i, rng), "dense_cat")
    clock.advance(10.0)
    for i in range(30):
        cache.insert(sp.sample(2000 + i, rng), "dense_cat", f"x{i}", f"y{i}")
    # sparse (low priority, unhit) should have lost more entries
    assert cache.category_count("sparse_cat") < 25


def test_l1_hot_documents_serve_without_store(rng):
    cache, _ = make_cache(l1=8)
    sp = tight(make_dense_space(seed=8))
    cache.insert(sp.sample(0, rng), "dense_cat", "q", "r")
    r1 = cache.lookup(sp.sample(0, rng), "dense_cat")
    r2 = cache.lookup(sp.sample(0, rng), "dense_cat")
    r3 = cache.lookup(sp.sample(0, rng), "dense_cat")
    assert r1.hit and r2.hit and r3.hit
    assert r3.reason == "hit_l1"            # promoted after ≥2 hits
    assert r3.response == "r"


def test_memory_report_matches_paper_budget(rng):
    cache, _ = make_cache(index_kind="hnsw")
    sp = make_dense_space(seed=9)
    for i in range(64):
        cache.insert(sp.sample(i, rng), "dense_cat", "q" * 100, "r" * 2000)
    rep = cache.memory_report()
    # §5.1: ~2 KB/entry in memory (384-d fp32 + graph + 112 B overhead)
    assert 1536 <= rep["in_memory_bytes_per_entry"] <= 4096
    assert rep["metadata_overhead_bytes"] == 112
    # documents (≈2 KB here) stay external
    assert rep["external_doc_bytes_per_entry"] > 1500


def test_batch_lookup_mixed_categories(rng):
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=10))
    cache.insert(sp.sample(0, rng), "dense_cat", "q0", "r0")
    cache.insert(sp.sample(1, rng), "sparse_cat", "q1", "r1")
    embs = np.stack([sp.sample(0, rng), sp.sample(1, rng), sp.sample(99, rng)])
    res = cache.lookup_batch(
        embs, ["dense_cat", "sparse_cat", "restricted"])
    assert res[0].hit and res[0].response == "r0"
    assert res[1].hit and res[1].response == "r1"
    assert not res[2].hit and res[2].reason == "compliance"


def test_category_isolation_no_cross_category_hits(rng):
    """A cached entry in category A must not serve category B."""
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=11))
    cache.insert(sp.sample(0, rng), "dense_cat", "q", "r")
    res = cache.lookup(sp.sample(0, rng), "sparse_cat")
    assert not res.hit
