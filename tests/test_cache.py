"""Algorithm 1 behavior: compliance, thresholds, TTL, quotas, eviction, L1."""

import numpy as np
import pytest

from repro.core import SemanticCache, SimClock
from dataclasses import replace as dc_replace

from repro.core.embedding import make_dense_space, make_sparse_space


def tight(space):
    """Mixture-free variant: mechanics tests want deterministic hits."""
    return dc_replace(space, loose_frac=0.0)
from repro.core.hnsw import INVALID
from repro.core.policy import CategoryConfig, PolicyEngine


def make_cache(capacity=512, index_kind="flat", l1=0, policies=None):
    eng = policies or PolicyEngine([
        CategoryConfig("dense_cat", threshold=0.90, ttl=3600.0, quota=0.5,
                       priority=4.0),
        CategoryConfig("sparse_cat", threshold=0.75, ttl=600.0, quota=0.3),
        CategoryConfig("restricted", threshold=0.9, ttl=60.0, quota=0.1,
                       allow_caching=False),
    ])
    clock = SimClock()
    return SemanticCache(eng, capacity=capacity, clock=clock,
                         index_kind=index_kind, l1_capacity=l1), clock


def test_hit_on_paraphrase_miss_on_distinct_intent(rng):
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=3))
    for i in range(50):
        cache.insert(sp.sample(i, rng), "dense_cat", f"q{i}", f"r{i}")
    hits = sum(cache.lookup(sp.sample(i, rng), "dense_cat").hit
               for i in range(50))
    assert hits >= 45                       # paraphrases above τ=0.90
    miss = cache.lookup(sp.sample(1234, rng), "dense_cat")
    # with category-masked search there is no "category_mismatch" anymore:
    # a distinct intent is a plain no_match
    assert not miss.hit and miss.reason == "no_match"


def test_compliance_never_stores_or_serves(rng):
    cache, _ = make_cache()
    emb = make_dense_space(seed=1).sample(0, rng)
    assert cache.insert(emb, "restricted", "q", "r") == INVALID
    assert len(cache) == 0                  # no temporary data presence
    res = cache.lookup(emb, "restricted")
    assert not res.hit and res.reason == "compliance"
    assert cache.metrics.cat("restricted").compliance_rejects >= 1


def test_ttl_validated_before_fetch(rng):
    cache, clock = make_cache()
    sp = tight(make_dense_space(seed=2))
    cache.insert(sp.sample(0, rng), "sparse_cat", "q", "r")
    assert cache.lookup(sp.sample(0, rng), "sparse_cat").hit
    clock.advance(601.0)                    # sparse_cat ttl = 600
    res = cache.lookup(sp.sample(0, rng), "sparse_cat")
    assert not res.hit and res.reason == "expired"
    # expired entry was evicted, not just skipped
    assert cache.metrics.cat("sparse_cat").ttl_evictions == 1
    assert len(cache) == 0


def test_per_category_thresholds_applied(rng):
    """Same geometric distance hits for the loose category only."""
    eng = PolicyEngine([
        CategoryConfig("tight", threshold=0.92, ttl=1e6, quota=0.5),
        CategoryConfig("loose", threshold=0.70, ttl=1e6, quota=0.5),
    ])
    cache, _ = make_cache(policies=eng)
    sp = make_sparse_space(seed=5)          # paraphrase cos ≈ 0.80
    rng2 = np.random.default_rng(7)
    # disjoint intents per category so top-1 stays within-category
    for i in range(20):
        cache.insert(sp.sample(i, rng2), "tight", f"q{i}", f"r{i}")
        cache.insert(sp.sample(100 + i, rng2), "loose", f"q{i}", f"r{i}")
    tight_hits = sum(cache.lookup(sp.sample(i, rng2), "tight").hit
                     for i in range(20))
    loose_hits = sum(cache.lookup(sp.sample(100 + i, rng2), "loose").hit
                     for i in range(20))
    assert loose_hits >= 15
    assert tight_hits <= 6


def test_quota_enforced_per_category(rng):
    cache, _ = make_cache(capacity=100)
    sp = make_dense_space(seed=4)
    for i in range(80):
        cache.insert(sp.sample(i, rng), "sparse_cat", f"q{i}", f"r{i}")
    # quota 0.3 × 100 = 30
    assert cache.category_count("sparse_cat") <= 30
    assert cache.metrics.cat("sparse_cat").quota_evictions > 0


def test_capacity_eviction_prefers_low_value(rng):
    cache, clock = make_cache(capacity=60)
    sp = make_dense_space(seed=6)
    # dense_cat has priority 4.0, sparse_cat 1.0
    for i in range(25):
        cache.insert(sp.sample(i, rng), "dense_cat", f"dq{i}", f"dr{i}")
    for i in range(25):
        cache.insert(sp.sample(1000 + i, rng), "sparse_cat", f"sq{i}", f"sr{i}")
    # hit the dense entries to raise their value
    for i in range(25):
        cache.lookup(sp.sample(i, rng), "dense_cat")
    clock.advance(10.0)
    for i in range(30):
        cache.insert(sp.sample(2000 + i, rng), "dense_cat", f"x{i}", f"y{i}")
    # sparse (low priority, unhit) should have lost more entries
    assert cache.category_count("sparse_cat") < 25


def test_l1_hot_documents_serve_without_store(rng):
    cache, _ = make_cache(l1=8)
    sp = tight(make_dense_space(seed=8))
    cache.insert(sp.sample(0, rng), "dense_cat", "q", "r")
    r1 = cache.lookup(sp.sample(0, rng), "dense_cat")
    r2 = cache.lookup(sp.sample(0, rng), "dense_cat")
    r3 = cache.lookup(sp.sample(0, rng), "dense_cat")
    assert r1.hit and r2.hit and r3.hit
    assert r3.reason == "hit_l1"            # promoted after ≥2 hits
    assert r3.response == "r"


def test_memory_report_matches_paper_budget(rng):
    cache, _ = make_cache(index_kind="hnsw")
    sp = make_dense_space(seed=9)
    for i in range(64):
        cache.insert(sp.sample(i, rng), "dense_cat", "q" * 100, "r" * 2000)
    rep = cache.memory_report()
    # §5.1: ~2 KB/entry in memory (384-d fp32 + graph + 112 B overhead)
    assert 1536 <= rep["in_memory_bytes_per_entry"] <= 4096
    assert rep["metadata_overhead_bytes"] == 112
    # documents (≈2 KB here) stay external
    assert rep["external_doc_bytes_per_entry"] > 1500


def test_batch_lookup_mixed_categories(rng):
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=10))
    cache.insert(sp.sample(0, rng), "dense_cat", "q0", "r0")
    cache.insert(sp.sample(1, rng), "sparse_cat", "q1", "r1")
    embs = np.stack([sp.sample(0, rng), sp.sample(1, rng), sp.sample(99, rng)])
    res = cache.lookup_batch(
        embs, ["dense_cat", "sparse_cat", "restricted"])
    assert res[0].hit and res[0].response == "r0"
    assert res[1].hit and res[1].response == "r1"
    assert not res[2].hit and res[2].reason == "compliance"


def test_category_isolation_no_cross_category_hits(rng):
    """A cached entry in category A must not serve category B."""
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=11))
    cache.insert(sp.sample(0, rng), "dense_cat", "q", "r")
    res = cache.lookup(sp.sample(0, rng), "sparse_cat")
    assert not res.hit


def _two_entry_embeddings(dim=384):
    """Query q, a cross-category entry at cos 1.0, a same-category entry
    at cos ≈ 0.95 (above dense_cat's τ = 0.90 but NOT the global nearest)."""
    q = np.zeros(dim, np.float32)
    q[0] = 1.0
    e_cross = q.copy()                       # global nearest, other category
    e_same = np.zeros(dim, np.float32)       # runner-up, same category
    e_same[0] = 0.95
    e_same[1] = np.sqrt(1.0 - 0.95 ** 2)
    return q, e_cross, e_same


@pytest.mark.parametrize("index_kind", ["flat", "hnsw"])
def test_same_category_hit_despite_nearer_cross_category(rng, index_kind):
    """Regression for the seed's category_mismatch false miss: the global
    nearest neighbor belongs to another category, but a same-category entry
    above τ sits one position behind — it MUST hit (§5.3 category-masked
    search), not be shadowed into a miss."""
    cache, _ = make_cache(index_kind=index_kind)
    q, e_cross, e_same = _two_entry_embeddings()
    cache.insert(e_cross, "sparse_cat", "qx", "rx")
    cache.insert(e_same, "dense_cat", "qs", "rs")
    res = cache.lookup(q, "dense_cat")
    assert res.hit, f"false miss (reason={res.reason!r}, score={res.score})"
    assert res.response == "rs"
    assert res.score == pytest.approx(0.95, abs=1e-3)
    assert res.reason == "hit"
    # the sparse query still gets its own entry, not the dense one
    res2 = cache.lookup(q, "sparse_cat")
    assert res2.hit and res2.response == "rx"


def test_same_category_hit_device_beam_search(rng):
    """Same regression through the jitted device beam search path."""
    eng = PolicyEngine([
        CategoryConfig("dense_cat", threshold=0.90, ttl=3600.0, quota=0.5),
        CategoryConfig("sparse_cat", threshold=0.75, ttl=600.0, quota=0.5),
    ])
    cache = SemanticCache(eng, capacity=256, clock=SimClock(),
                          index_kind="hnsw", use_device=True)
    q, e_cross, e_same = _two_entry_embeddings()
    cache.insert(e_cross, "sparse_cat", "qx", "rx")
    cache.insert(e_same, "dense_cat", "qs", "rs")
    # pad the graph so the beam search has something to traverse
    sp = tight(make_dense_space(seed=12))
    for i in range(30):
        cache.insert(sp.sample(i, rng), "sparse_cat", f"p{i}", f"pr{i}")
    res = cache.lookup_batch(np.stack([q, q]),
                             ["dense_cat", "sparse_cat"])
    assert res[0].hit, f"false miss (reason={res[0].reason!r})"
    assert res[0].response == "rs"
    assert res[1].hit and res[1].response == "rx"


def test_insert_batch_matches_sequential_inserts(rng):
    """One insert_batch must leave the cache in the same state as the
    equivalent sequence of single inserts: same occupancy, same category
    counts, same hits on lookup."""
    sp = tight(make_dense_space(seed=20))
    rng2 = np.random.default_rng(20)
    embs = np.stack([sp.sample(i, rng2) for i in range(40)])
    cats = ["dense_cat" if i % 2 == 0 else "sparse_cat" for i in range(40)]

    seq, _ = make_cache()
    for i in range(40):
        seq.insert(embs[i], cats[i], f"q{i}", f"r{i}")
    bat, _ = make_cache()
    slots = bat.insert_batch(embs, cats, [f"q{i}" for i in range(40)],
                             [f"r{i}" for i in range(40)])
    assert len(bat) == len(seq)
    assert all(s >= 0 for s in slots)
    for c in ("dense_cat", "sparse_cat"):
        assert bat.category_count(c) == seq.category_count(c)
        assert bat.metrics.cat(c).inserts == seq.metrics.cat(c).inserts
    for i in range(40):
        r = bat.lookup(embs[i], cats[i])
        assert r.hit and r.response == f"r{i}"


def test_insert_batch_compliance_rejected_items_get_invalid(rng):
    cache, _ = make_cache()
    sp = tight(make_dense_space(seed=21))
    embs = np.stack([sp.sample(i, rng) for i in range(3)])
    slots = cache.insert_batch(embs, ["dense_cat", "restricted", "sparse_cat"],
                               ["a", "b", "c"], ["ra", "rb", "rc"])
    assert slots[0] >= 0 and slots[2] >= 0
    assert slots[1] == INVALID
    assert len(cache) == 2          # no temporary presence for restricted
    assert cache.metrics.cat("restricted").insert_rejects == 1


def test_insert_batch_quota_enforced_within_one_batch(rng):
    """A single batch that overflows a category quota must end at the
    quota, evicting earlier batch items (seed semantics: each overflowing
    insert evicts the lowest-scored same-category entry)."""
    cache, _ = make_cache(capacity=100)
    sp = make_dense_space(seed=22)
    n = 80                          # quota 0.3 x 100 = 30
    embs = np.stack([sp.sample(i, rng) for i in range(n)])
    cache.insert_batch(embs, ["sparse_cat"] * n,
                       [f"q{i}" for i in range(n)],
                       [f"r{i}" for i in range(n)])
    assert cache.category_count("sparse_cat") <= 30
    assert cache.metrics.cat("sparse_cat").quota_evictions > 0
    assert cache.metrics.cat("sparse_cat").inserts == n
    # the store holds exactly the surviving documents
    assert len(cache.store) == len(cache)


def test_insert_batch_one_store_pass_and_one_delta_flush(rng):
    """B inserts = one put_many call and one device sync."""
    from repro.core.storage import InMemoryStore

    class CountingStore(InMemoryStore):
        def __init__(self):
            super().__init__()
            self.put_calls = 0
            self.put_many_calls = 0

        def put(self, doc):
            self.put_calls += 1
            super().put(doc)

        def put_many(self, docs):
            self.put_many_calls += 1
            super().put_many(docs)

    eng = PolicyEngine([
        CategoryConfig("dense_cat", threshold=0.90, ttl=3600.0, quota=1.0),
    ])
    store = CountingStore()
    cache = SemanticCache(eng, capacity=4096, clock=SimClock(),
                          index_kind="hnsw", use_device=True, store=store)
    sp = tight(make_dense_space(seed=23))
    warm = np.stack([sp.sample(1000 + i, rng) for i in range(32)])
    cache.insert_batch(warm, ["dense_cat"] * 32,
                       [f"w{i}" for i in range(32)],
                       [f"wr{i}" for i in range(32)])
    cache.lookup_batch(warm[:4], ["dense_cat"] * 4)   # initial upload
    syncs0 = (cache.index.sync_stats["full_uploads"]
              + cache.index.sync_stats["delta_updates"])
    calls0 = store.put_many_calls

    embs = np.stack([sp.sample(i, rng) for i in range(16)])
    cache.insert_batch(embs, ["dense_cat"] * 16,
                       [f"q{i}" for i in range(16)],
                       [f"r{i}" for i in range(16)])
    res = cache.lookup_batch(embs, ["dense_cat"] * 16)
    syncs1 = (cache.index.sync_stats["full_uploads"]
              + cache.index.sync_stats["delta_updates"])
    assert store.put_many_calls == calls0 + 1
    assert store.put_calls == 0                 # batched, not looped
    assert syncs1 == syncs0 + 1                 # ONE flush for 16 inserts
    assert sum(r.hit for r in res) >= 12        # ANN beam recall


def test_batch_no_false_miss_across_interleaved_categories(rng):
    """Mixed-category batch where every query's global nearest is the OTHER
    category's entry: all queries must still hit their own category."""
    cache, _ = make_cache()
    dim = 384
    B = 8
    embs, cats = [], []
    for k in range(B):
        q = np.zeros(dim, np.float32)
        q[2 * k] = 1.0
        near = np.zeros(dim, np.float32)     # cross-category, cos ≈ 0.99
        near[2 * k] = 0.99
        near[2 * k + 1] = np.sqrt(1 - 0.99 ** 2)
        own = np.zeros(dim, np.float32)      # same-category, cos ≈ 0.93
        own[2 * k] = 0.93
        own[2 * k + 1] = -np.sqrt(1 - 0.93 ** 2)
        me, other = ("dense_cat", "sparse_cat") if k % 2 == 0 else \
            ("sparse_cat", "dense_cat")
        cache.insert(near, other, f"near{k}", f"nr{k}")
        cache.insert(own, me, f"own{k}", f"or{k}")
        embs.append(q)
        cats.append(me)
    results = cache.lookup_batch(np.stack(embs), cats)
    for k, res in enumerate(results):
        assert res.hit, f"query {k} false miss (reason={res.reason!r})"
        assert res.response == f"or{k}"
