"""Replica-aware serving (core/shard.py replication layer + self-healing).

Four property families pin the PR's guarantees:

* **Placement** — the planner's replication pass puts k copies on k
  distinct shards, LPT still balancing TOTAL placed bytes (copies carry
  real weight), and the spec is capped at the shard count.
* **Zero correctness drift** — a replicated sharded cache is
  bit-identical to the single-cache oracle across {1,2,4} shards ×
  {flat,hnsw} × {fp32,int8}: round-robin reads mean every replica
  answers the trace, so trace equality IS replica equality. Write
  catch-up after an outage converges the recovered replica to its
  siblings' exact entry set, timestamps included (back-dated to the
  acknowledgment instant), with ``replica_divergence == 0``.
* **Failover availability** — an outage on any one replica serves hits,
  not degraded_misses (``failover_reads`` counted, availability 1.0),
  and the round-robin read assignment is byte-identical across two
  identical runs, outage/recovery cycle included.
* **Self-healing** — the write-behind replay path and the journaled
  ``OutageRebalance`` (store rebuild → flip → wb drain) survive an
  injected crash at EVERY enumerable index with acknowledged writes
  applied exactly once, and a recovered shard demotes its stale copies
  and re-absorbs the category.
"""

import numpy as np
import pytest

from repro.core import (FaultInjector, FaultSchedule, InjectedCrash,
                        SemanticCache, ShardedSemanticCache, SimClock)
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.core.shard import CRC32Planner, ShardPlanner

DIM = 48


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=1e6, quota=0.40),
        CategoryConfig("b", threshold=0.78, ttl=1e6, quota=0.40),
        CategoryConfig("d", threshold=0.95, ttl=1.0, quota=0.0,
                       allow_caching=False),
    ])


def _bank(cat: str, n: int = 64) -> np.ndarray:
    rng = np.random.default_rng({"a": 100, "b": 101, "d": 102}[cat])
    v = rng.standard_normal((n, DIM)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _sharded(n_shards=2, faults=None, index_kind="flat",
             emb_dtype="float32", clock=None, **kw):
    return ShardedSemanticCache(
        _policies(), dim=DIM, capacity=256, n_shards=n_shards,
        clock=clock or SimClock(), index_kind=index_kind,
        emb_dtype=emb_dtype, seed=0, faults=faults, **kw)


def _cat_state(shard: SemanticCache, cat: str) -> dict:
    """response -> (inserted timestamp, hit count) for every resident
    entry — the bit-level replica-convergence fingerprint."""
    out = {}
    for s in shard.category_slots(cat):
        doc = shard.store.get(int(shard.slot_doc[s]))
        out[doc.response] = (float(shard.slot_inserted[s]),
                             int(shard.slot_hits[s]))
    return out


# ----------------------------------------------------------------- placement
class TestReplicationPlanner:
    def _planner(self, n_shards=4, replication=None) -> ShardPlanner:
        return ShardPlanner.from_policies(_policies(), n_shards, 256,
                                          dim=DIM,
                                          replication=replication)

    def test_no_replication_is_single_home(self):
        p = self._planner()
        assert p.replica_sets == {}
        for c in ("a", "b", "d"):
            assert p.replica_set(c) == [p.shard_of(c)]

    def test_explicit_map_places_k_distinct_shards(self):
        p = self._planner(replication={"a": 3})
        reps = p.replica_set("a")
        assert len(reps) == 3 and len(set(reps)) == 3
        assert reps[0] == p.shard_of("a")       # primary leads
        assert p.replica_set("b") == [p.shard_of("b")]
        assert p.report()["replica_sets"] == {"a": reps}

    def test_threshold_replicates_head_categories(self):
        p = self._planner(replication=0.40)     # a and b both at 0.40
        assert len(p.replica_set("a")) == 2
        assert len(p.replica_set("b")) == 2
        assert p.replica_set("d") == [p.shard_of("d")]  # zero quota

    def test_replica_weight_counts_toward_bins(self):
        none = self._planner()
        repl = self._planner(replication={"a": 3})
        extra = sum(repl.shard_bytes) - sum(none.shard_bytes)
        assert extra == 2 * repl.quota_bytes(0.40)
        # the copies landed on the lightest bins, keeping the spread flat
        assert repl.imbalance() <= none.imbalance() + 1e-9

    def test_spec_capped_at_shard_count(self):
        p = self._planner(n_shards=2, replication={"a": 8})
        assert len(p.replica_set("a")) == 2

    def test_crc32_planner_is_single_home(self):
        p = CRC32Planner(4)
        assert p.replica_set("a") == [p.shard_of("a")]


# --------------------------------------------------- zero correctness drift
def _run_trace(cache, rounds=8, per_cat=12):
    """Mixed lookup/insert workload with enough volume to churn the
    quota ceiling (0.40 × 256 ≈ 102 entries/category), so eviction
    determinism across replicas is part of the fingerprint."""
    bank_a, bank_b = _bank("a", 128), _bank("b", 128)
    trace = []
    for r in range(rounds):
        lo = r * per_cat
        embs = np.concatenate([bank_a[lo:lo + per_cat],
                               bank_b[lo:lo + per_cat]])
        cats = ["a"] * per_cat + ["b"] * per_cat
        res = cache.lookup_batch(embs, cats)
        trace.append([(x.hit, x.reason, x.response) for x in res])
        miss = [i for i, x in enumerate(res) if not x.hit]
        if miss:
            cache.insert_batch(embs[miss], [cats[i] for i in miss],
                               [f"q{r}.{i}" for i in miss],
                               [f"r{r}.{i}" for i in miss])
        res2 = cache.lookup_batch(embs, cats)   # re-read: all resident
        trace.append([(x.hit, x.reason, x.response) for x in res2])
    per = cache.metrics.per_category if hasattr(cache.metrics,
                                                "per_category") else None
    counters = {c: (per[c].lookups, per[c].hits, per[c].misses)
                for c in ("a", "b")}
    return trace, counters


@pytest.mark.parametrize("index_kind,emb_dtype", [
    ("flat", "float32"), ("flat", "int8"),
    ("hnsw", "float32"), ("hnsw", "int8"),
])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_replicated_parity_with_single_cache(n_shards, index_kind,
                                             emb_dtype):
    """Round-robin spreads the read stream across every replica, so
    trace equality with the single-cache oracle proves the replicas
    answer bit-identically — entry sets, TTL classification, eviction
    victims and all."""
    single = SemanticCache(_policies(), dim=DIM, capacity=256,
                           clock=SimClock(), index_kind=index_kind,
                           emb_dtype=emb_dtype, seed=0)
    sharded = _sharded(n_shards=n_shards, index_kind=index_kind,
                       emb_dtype=emb_dtype,
                       replication={"a": 2, "b": 2})
    assert _run_trace(sharded) == _run_trace(single)
    assert sharded.fault_stats["replica_divergence"] == 0


def test_replicas_converge_after_write_catchup():
    """Writes fanned out while one replica is down catch the replica up
    on recovery DIRECTLY (never through the front door — the sibling
    already applied them), back-dated to the acknowledgment instant:
    both replicas end bit-identical in entries, timestamps and hits."""
    clk = SimClock()
    inj = FaultInjector(FaultSchedule(shard_outages=[(1.0, 5.0, 1)]), clk)
    cache = _sharded(faults=inj, clock=clk, replication={"a": 2})
    reps = cache.replica_set("a")
    assert sorted(reps) == [0, 1]
    bank = _bank("a")
    cache.insert_batch(bank[:4], ["a"] * 4,
                       [f"q{i}" for i in range(4)],
                       [f"r{i}" for i in range(4)])
    clk.advance(2.0)                    # into the outage window
    cache.insert_batch(bank[4:8], ["a"] * 4,
                       [f"q{i}" for i in range(4, 8)],
                       [f"r{i}" for i in range(4, 8)])
    assert cache.fault_stats["wb_enqueued"] == 4
    # reads keep hitting through the live replica meanwhile
    res = cache.lookup_batch(bank[:8], ["a"] * 8)
    assert all(r.hit for r in res)
    assert cache.metrics.cat("a").degraded_misses == 0
    clk.advance(10.0)                   # recovery; next op replays
    res = cache.lookup_batch(bank[:8], ["a"] * 8)
    assert all(r.hit for r in res)
    assert cache.wb_pending == 0
    assert _cat_state(cache.shards[0], "a") == \
        _cat_state(cache.shards[1], "a")
    # post-recovery round-robin serves from BOTH replicas, drift-free
    for _ in range(4):
        assert all(r.hit for r in cache.lookup_batch(bank[:8], ["a"] * 8))
    assert cache.fault_stats["replica_divergence"] == 0


# -------------------------------------------------------- failover + routing
def test_outage_fails_reads_over_not_degrades():
    clk = SimClock()
    inj = FaultInjector(FaultSchedule(shard_outages=[(1.0, 3.0, 0),
                                                     (4.0, 6.0, 1)]), clk)
    cache = _sharded(faults=inj, clock=clk, replication={"a": 2})
    bank = _bank("a")
    cache.insert_batch(bank[:6], ["a"] * 6,
                       [f"q{i}" for i in range(6)],
                       [f"r{i}" for i in range(6)])
    for t in (1.5, 4.5):                # each replica down in turn
        while clk.now() < t:
            clk.advance(t - clk.now())
        res = cache.lookup_batch(bank[:6], ["a"] * 6)
        assert all(r.hit for r in res)
    st = cache.metrics.cat("a")
    assert st.degraded_misses == 0 and st.availability == 1.0
    assert cache.fault_stats["failover_reads"] > 0
    assert cache.fault_stats["replica_divergence"] == 0
    # the failing-over reads were recorded against live shards only
    assert all(s in (0, 1) for s in cache.last_read_shards)


def test_read_routing_is_deterministic_across_runs():
    """Fixed seed + fixed schedule ⇒ byte-identical round-robin read
    assignment and identical counters across two runs, through a full
    outage/recovery cycle."""
    def run():
        clk = SimClock()
        inj = FaultInjector(
            FaultSchedule(shard_outages=[(1.0, 3.0, 0)]), clk)
        cache = _sharded(faults=inj, clock=clk, replication={"a": 2})
        bank_a, bank_b = _bank("a"), _bank("b")
        routing = []
        for r in range(10):
            embs = np.concatenate([bank_a[r:r + 3], bank_b[r:r + 3]])
            cats = ["a"] * 3 + ["b"] * 3
            res = cache.lookup_batch(embs, cats)
            routing.append(list(cache.last_read_shards))
            miss = [i for i, x in enumerate(res) if not x.hit]
            if miss:
                cache.insert_batch(embs[miss], [cats[i] for i in miss],
                                   [f"q{r}.{i}" for i in miss],
                                   [f"r{r}.{i}" for i in miss])
            clk.advance(0.5)            # crosses outage start AND end
        return (routing, dict(cache.fault_stats),
                cache.metrics.snapshot(), clk.now())
    assert run() == run()


def test_degraded_seconds_accrues_observed_window():
    """Per-category degraded_seconds: the observed wall time between the
    first op that found no live replica and the first op that found one
    — replicated categories accrue zero through a single-shard outage."""
    clk = SimClock()
    inj = FaultInjector(FaultSchedule(shard_outages=[(1.0, 3.0, 0),
                                                     (1.0, 3.0, 1)]), clk)
    cache = _sharded(faults=inj, clock=clk, replication={"a": 2})
    bank_a = _bank("a")
    for t in (0.5, 1.5, 2.5, 3.5):
        while clk.now() < t:
            clk.advance(t - clk.now())
        cache.lookup_batch(bank_a[:2], ["a"] * 2)
    st = cache.metrics.cat("a")
    # both replicas down 1.0-3.0: observed from the t=1.5 op to the
    # t=3.5 op (ops, not the schedule, bound the observation)
    assert 1.9 < st.degraded_seconds < 2.2
    assert st.degraded_misses == 4      # t=1.5 and t=2.5 batches
    rep = cache.metrics.slo_report()
    assert rep["a"]["replicas"] == 2
    assert rep["a"]["degraded_seconds"] == round(st.degraded_seconds, 3)

    # single-shard outage on a replicated category: zero accrual
    clk2 = SimClock()
    inj2 = FaultInjector(FaultSchedule(shard_outages=[(1.0, 3.0, 0)]),
                         clk2)
    cache2 = _sharded(faults=inj2, clock=clk2, replication={"a": 2})
    for t in (0.5, 1.5, 2.5, 3.5):
        while clk2.now() < t:
            clk2.advance(t - clk2.now())
        cache2.lookup_batch(bank_a[:2], ["a"] * 2)
    assert cache2.metrics.cat("a").degraded_seconds == 0.0


def test_replicated_categories_are_pinned():
    cache = _sharded(replication={"a": 2})
    with pytest.raises(RuntimeError, match="pinned"):
        cache.migrate_category("a", 1)
    assert "a" not in cache.rebalance()         # re-plan skips it too
    assert sorted(cache.replica_set("a")) == [0, 1]


# ------------------------------------------------- exactly-once wb replay
def _wb_crash_setup(inj):
    """Outage on shard 1 (= b's home AND a's replica) queues BOTH item
    modes: 4 replica-mode catch-ups for "a", 4 front-door items for
    "b". Returns (cache, clk, bank_a, bank_b)."""
    clk = SimClock()
    inj.clock = clk
    cache = _sharded(faults=inj, clock=clk, replication={"a": 2})
    assert cache.replica_set("a") == [0, 1]
    assert cache.shard_of("b") == 1
    bank_a, bank_b = _bank("a"), _bank("b")
    embs = np.concatenate([bank_a[:4], bank_b[:4]])
    cats = ["a"] * 4 + ["b"] * 4
    cache.insert_batch(embs, cats, [f"q{i}" for i in range(8)],
                       [f"r{i}" for i in range(8)])
    assert cache.fault_stats["wb_enqueued"] == 8
    return cache, clk, bank_a, bank_b


def _wb_replay_visits() -> int:
    inj = FaultInjector(FaultSchedule(shard_outages=[(0.0, 5.0, 1)],
                                      crash_at={"elsewhere": 0}))
    cache, clk, bank_a, bank_b = _wb_crash_setup(inj)
    clk.advance(10.0)
    cache.lookup_batch(bank_a[:1], ["a"])
    assert cache.wb_pending == 0
    return inj.visits("wb_replay")


def test_wb_replay_crash_at_every_index():
    """Satellite tentpole: a crash at EVERY enumerable index inside the
    item-by-item write-behind replay loop — acknowledged writes are
    never lost and never double-applied once replay finishes."""
    n = _wb_replay_visits()
    assert n == 16                      # 8 items × crash sites before/after
    for k in range(n):
        inj = FaultInjector(FaultSchedule(shard_outages=[(0.0, 5.0, 1)],
                                          crash_at={"wb_replay": k}))
        cache, clk, bank_a, bank_b = _wb_crash_setup(inj)
        clk.advance(10.0)
        with pytest.raises(InjectedCrash):
            cache.lookup_batch(bank_a[:1], ["a"])
        # recovery: the disarmed injector lets the next op finish replay
        cache.lookup_batch(bank_a[:1], ["a"])
        assert cache.wb_pending == 0, k
        fd = cache.fault_stats
        assert fd["wb_replayed"] == fd["wb_enqueued"] == 8, k
        # exactly once: each replica holds each "a" write ONCE, the
        # recovered home holds each "b" write ONCE
        assert cache.shards[0].category_count("a") == 4
        assert cache.shards[1].category_count("a") == 4
        assert cache.category_count("b") == 4
        # replica catch-up back-dated timestamps: bit-identical siblings
        assert _cat_state(cache.shards[0], "a") == \
            _cat_state(cache.shards[1], "a")
        embs = np.concatenate([bank_a[:4], bank_b[:4]])
        res = cache.lookup_batch(embs, ["a"] * 4 + ["b"] * 4)
        assert all(r.hit for r in res), k


# -------------------------------------------------- self-healing rebalance
def _rebalance_setup(inj, n_seed=12):
    """Category "a" seeded pre-outage on its home shard; the outage
    (2s-30s) outlives rebalance_after_s=1.0, and 3 more writes are
    acknowledged into the write-behind queue mid-outage."""
    clk = SimClock()
    inj.clock = clk
    cache = _sharded(faults=inj, clock=clk, rebalance_after_s=1.0)
    src = cache.shard_of("a")
    bank = _bank("a")
    cache.insert_batch(bank[:n_seed], ["a"] * n_seed,
                       [f"q{i}" for i in range(n_seed)],
                       [f"r{i}" for i in range(n_seed)])
    clk.advance(2.5)                    # outage starts at 2.0
    cache.insert_batch(bank[n_seed:n_seed + 3], ["a"] * 3,
                       ["wq0", "wq1", "wq2"], ["wr0", "wr1", "wr2"])
    clk.advance(1.5)                    # past the 1.0 s threshold
    return cache, clk, bank, src


def _outage_schedule(src, crash_at=None):
    return FaultSchedule(shard_outages=[(2.0, 30.0, src)],
                         crash_at=crash_at or {"elsewhere": 0})


def _rebalance_visits(src) -> int:
    inj = FaultInjector(_outage_schedule(src))
    cache, clk, bank, _ = _rebalance_setup(inj)
    cache.lookup_batch(bank[:1], ["a"])     # triggers the rebalance
    assert cache.fault_stats["outage_rebalances"] == 1
    return inj.visits("outage_rebalance")


def test_outage_rebalance_end_to_end():
    """Sustained outage evacuates the unreplicated category via store
    rebuild + wb drain; lookups serve from the new owner inside the
    outage window; the recovered shard demotes its stale copies and the
    category re-absorbs to its original home."""
    src = _sharded().shard_of("a")
    inj = FaultInjector(_outage_schedule(src))
    cache, clk, bank, _ = _rebalance_setup(inj)
    res = cache.lookup_batch(bank[:15], ["a"] * 15)
    assert all(r.hit for r in res)          # mid-outage, zero degraded!
    dst = cache.shard_of("a")
    assert dst != src
    assert cache.shards[dst].category_count("a") == 15
    assert cache.fault_stats["outage_rebalances"] == 1
    assert cache.wb_pending == 0
    st = cache.metrics.cat("a")
    # degraded window bounded by rebalance_after_s (1.0), not the 28 s
    # outage: the only degraded op is none — the trigger op itself
    # already served from the new owner
    assert st.degraded_seconds <= 1.5 + 0.1
    clk.advance(40.0)                       # outage ends; src recovers
    res = cache.lookup_batch(bank[:15], ["a"] * 15)
    assert all(r.hit for r in res)
    assert cache.shard_of("a") == src       # re-absorbed home
    assert cache.shards[src].category_count("a") == 15
    assert cache.shards[dst].category_count("a") == 0
    assert cache.fault_stats["reabsorbed_categories"] == 1
    assert "a" not in cache._moved_by_outage


def test_outage_rebalance_crash_at_every_step():
    """The hard part: source-side state is reconstructed from the store
    + write-behind queue while the owner is DOWN. A crash at every
    enumerable protocol index, recovered in both modes, must leave one
    authoritative owner holding every acknowledged write exactly once."""
    src = _sharded().shard_of("a")
    n_steps = _rebalance_visits(src)
    assert n_steps >= 8                     # rebuild batches + drain items
    for k in range(n_steps):
        for mode in ("resume", "abort"):
            inj = FaultInjector(
                _outage_schedule(src, crash_at={"outage_rebalance": k}))
            cache, clk, bank, _ = _rebalance_setup(inj)
            with pytest.raises(InjectedCrash):
                cache.lookup_batch(bank[:1], ["a"])
            reb = cache._migrations.get("a")
            assert reb is not None and not reb.done
            actions = cache.recover_migrations(mode)
            if actions["a"] == "aborted":
                # pre-flip rollback: the (down) source keeps authority;
                # wait out the outage so the queue replays to it
                assert cache.shard_of("a") == src and not reb.flipped
            else:
                # resumed: finished forward to the live target, hits
                # flow mid-outage (the dead source still holds its
                # stale in-memory copies until recovery demotes them)
                owner = cache.shard_of("a")
                assert owner != src
                res = cache.lookup_batch(bank[:15], ["a"] * 15)
                assert all(r.hit for r in res), (k, mode)
                assert cache.shards[owner].category_count("a") == 15
            clk.advance(40.0)           # outage ends: demote + re-absorb
            res = cache.lookup_batch(bank[:15], ["a"] * 15)
            assert all(r.hit for r in res), (k, mode)
            assert cache.shard_of("a") == src, (k, mode)
            counts = [cache.shards[s].category_count("a")
                      for s in range(2)]
            assert counts[src] == 15 and sum(counts) == 15, (k, mode)
            assert cache.wb_pending == 0
            fd = cache.fault_stats
            assert fd["wb_replayed"] == fd["wb_enqueued"] == 3, (k, mode)


def test_rebalance_recovery_reabsorbs_after_resume():
    """After a crashed-then-resumed evacuation, the original shard's
    recovery still demotes stale copies and re-absorbs — the
    _moved_by_outage ledger survives the crash."""
    src = _sharded().shard_of("a")
    inj = FaultInjector(_outage_schedule(src,
                                         crash_at={"outage_rebalance": 3}))
    cache, clk, bank, _ = _rebalance_setup(inj)
    with pytest.raises(InjectedCrash):
        cache.lookup_batch(bank[:1], ["a"])
    if cache.recover_migrations("resume")["a"] == "aborted":
        pytest.skip("crash index landed pre-protocol")
    clk.advance(40.0)
    res = cache.lookup_batch(bank[:15], ["a"] * 15)
    assert all(r.hit for r in res)
    assert cache.shard_of("a") == src
    assert cache.shards[src].category_count("a") == 15
    assert cache.fault_stats["reabsorbed_categories"] == 1
