"""Observability pipeline (repro/obs): histograms, spans, accounting,
exports — plus the metrics satellites that ride with it.

The properties that matter:

* **Histogram fidelity** — fixed log-scale buckets give quantiles
  within one bucket width (~9% relative) of numpy's, means are exact,
  and merge is equivalent to observing the union.
* **Span accounting** — under ``SimClock`` every opened span closes
  and, for every root, leaf-descendant durations sum to the root
  duration exactly (all clock charges live in leaf spans). Leaks and
  gaps are detected, not silently absorbed.
* **Empty-recorder parity** — tracing OFF is bit-identical to the
  untraced build, and tracing ON changes no counter either (it only
  observes). Mirrors the fault injector's empty-schedule discipline.
* **Attribution** — a fault scenario's degraded windows are fully
  explained by ``degraded_accrue`` events; one ``degraded_miss`` event
  per counted degraded lookup.
* **Span lint** — any ``clock.advance`` in a traced module without a
  span (or pragma) is a static violation; the real tree is clean.
"""

import json
import math

import numpy as np
import pytest

from repro.analysis import span_lint
from repro.core import SemanticCache, ShardedSemanticCache, SimClock
from repro.core.faults import FaultSchedule
from repro.core.metrics import CategoryStats, MetricsRegistry, overall_row
from repro.core.policy import (CategoryConfig, PolicyEngine,
                               paper_policies)
from repro.core.workload import scenario_generator
from repro.obs import (LatencyHistogram, TraceRecorder,
                       check_span_accounting, coverage_fraction,
                       prometheus_text, span_accounting, telemetry_report)
from repro.obs.hist import (GROWTH, HistogramSet, bucket_of,
                            bucket_upper_ms)
from repro.obs.trace import NO_PARENT
from repro.serving.simulator import ServingSimulator, SimConfig

DIM = 48


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=1e6, quota=0.5),
        CategoryConfig("b", threshold=0.78, ttl=1e6, quota=0.5),
    ])


def _bank(seed: int, n: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n, DIM)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


# ---------------------------------------------------------------- histogram
class TestLatencyHistogram:
    def test_bucket_edges_bracket_sample(self):
        for ms in (1e-4, 1e-3, 0.0123, 1.0, 2.0, 37.5, 1e4, 1e6):
            i = bucket_of(ms)
            assert ms <= bucket_upper_ms(i) or i == bucket_of(1e9)
            if i > 0 and bucket_upper_ms(i) != math.inf:
                lower = bucket_upper_ms(i) / GROWTH
                assert lower < ms <= bucket_upper_ms(i)

    def test_quantiles_within_bucket_tolerance_of_numpy(self):
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=1.0, sigma=1.2, size=5000)
        h = LatencyHistogram()
        for s in samples:
            h.observe(float(s))
        for q in (0.50, 0.95, 0.99):
            exact = float(np.quantile(samples, q))
            approx = h.quantile(q)
            # one bucket of relative error (geometric midpoint)
            assert exact / GROWTH <= approx <= exact * GROWTH

    def test_mean_is_exact_and_minmax_tracked(self):
        h = LatencyHistogram()
        vals = [0.5, 2.0, 8.0, 32.0]
        for v in vals:
            h.observe(v)
        assert h.mean_ms == pytest.approx(sum(vals) / len(vals), abs=0)
        assert h.min_ms == 0.5 and h.max_ms == 32.0
        assert h.count == 4

    def test_merge_equivalent_to_union(self):
        rng = np.random.default_rng(1)
        a, b = LatencyHistogram(), LatencyHistogram()
        both = LatencyHistogram()
        for v in rng.lognormal(size=400):
            a.observe(float(v))
            both.observe(float(v))
        for v in rng.lognormal(size=300):
            b.observe(float(v))
            both.observe(float(v))
        a.merge(b)
        assert a.counts == both.counts
        assert a.count == both.count == 700
        assert a.sum_ms == pytest.approx(both.sum_ms)
        assert a.quantile(0.95) == both.quantile(0.95)

    def test_to_dict_shape(self):
        h = LatencyHistogram()
        h.observe(1.5)
        d = h.to_dict()
        assert d["count"] == 1 and d["sum_ms"] == 1.5
        assert list(d["buckets"].values()) == [1]

    def test_empty_quantile_is_zero(self):
        assert LatencyHistogram().quantile(0.99) == 0.0
        assert LatencyHistogram().mean_ms == 0.0

    def test_histogram_set_rollup(self):
        hs = HistogramSet()
        hs.observe("search", 1.0, category="a", shard=0)
        hs.observe("search", 2.0, category="b", shard=1)
        hs.observe("write", 4.0, category="a", shard=0)
        assert hs.stages() == ["search", "write"]
        assert hs.rollup(stage="search").count == 2
        assert hs.rollup(category="a").count == 2
        assert hs.rollup(stage="search", shard=1).count == 1
        assert hs.rollup().sum_ms == pytest.approx(7.0)
        assert len(hs.to_dict()) == 3


# ---------------------------------------------------------------- recorder
class TestTraceRecorder:
    def test_nesting_parent_ids_and_simclock_durations(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        with rec.span("root", category="a"):
            with rec.span("leaf1"):
                clock.advance(0.002)
            with rec.span("leaf2"):
                clock.advance(0.003)
        root, l1, l2 = rec.spans
        assert root.parent_id == NO_PARENT
        assert l1.parent_id == root.span_id == l2.parent_id
        assert l1.dur_ms == pytest.approx(2.0)
        assert l2.dur_ms == pytest.approx(3.0)
        assert root.dur_ms == pytest.approx(5.0)
        assert rec.opened == rec.closed == 3
        assert check_span_accounting(rec) == []
        assert coverage_fraction(rec) == pytest.approx(1.0)

    def test_span_closes_on_exception(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        with pytest.raises(RuntimeError):
            with rec.span("root"):
                clock.advance(0.001)
                raise RuntimeError("boom")
        assert rec.opened == rec.closed == 1
        assert rec.spans[0].dur_ms == pytest.approx(1.0)

    def test_leak_detected(self):
        rec = TraceRecorder(SimClock())
        rec.span("never_closed")            # no `with`, never exits
        out = check_span_accounting(rec)
        assert any("span leak" in v for v in out)

    def test_charge_outside_leaf_detected_as_gap(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        with rec.span("root"):
            with rec.span("leaf"):
                clock.advance(0.001)
            clock.advance(0.004)            # un-spanned: breaks accounting
        acc = span_accounting(rec)
        assert acc["gapped_roots"] and acc["max_gap_ms"] == pytest.approx(4.0)
        assert check_span_accounting(rec)
        assert coverage_fraction(rec) == pytest.approx(0.2)

    def test_events_and_counts(self):
        rec = TraceRecorder(SimClock())
        rec.event("eviction", reason="quota", category="a")
        rec.event("eviction", reason="ttl", category="b")
        rec.event("wb_enqueue", shard=1)
        assert rec.event_counts() == {"eviction": 2, "wb_enqueue": 1}
        assert rec.events[0].fields["reason"] == "quota"

    def test_childless_root_counts_its_own_duration(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        with rec.span("solo"):
            clock.advance(0.002)
        assert check_span_accounting(rec) == []


# ------------------------------------------------------- single-cache spans
class TestCacheSpans:
    def test_lookup_and_insert_span_structure(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        cache = SemanticCache(_policies(), dim=DIM, capacity=64,
                              clock=clock, seed=0, obs=rec)
        v = _bank(0, 8)
        cache.insert_batch(v, ["a"] * 8, [f"q{i}" for i in range(8)],
                           [f"r{i}" for i in range(8)])
        cache.lookup_batch(v[:4], ["a"] * 4)
        stages = {sp.stage for sp in rec.spans}
        assert {"insert", "gate", "write", "lookup", "search"} <= stages
        roots = [sp for sp in rec.spans if sp.parent_id == NO_PARENT]
        assert {sp.stage for sp in roots} == {"insert", "lookup"}
        assert check_span_accounting(rec) == []
        # store_fetch leaves fire on resolved hits
        assert any(sp.stage == "store_fetch" for sp in rec.spans)

    def test_eviction_event_emitted(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        cache = SemanticCache(_policies(), dim=DIM, capacity=8,
                              clock=clock, seed=0, obs=rec)
        v = _bank(1, 24)
        # two batches: the second must evict MATERIALIZED entries (same-
        # batch quota pressure only drops pending items, no slot evicted)
        for lo in (0, 12):
            cache.insert_batch(v[lo:lo + 12], ["a"] * 12,
                               [f"q{lo + i}" for i in range(12)],
                               [f"r{lo + i}" for i in range(12)])
        evc = rec.event_counts()
        assert evc.get("eviction", 0) > 0
        assert check_span_accounting(rec) == []


# ------------------------------------------------------- simulator parity
def _sim_cfg(trace: bool, schedule=None, **kw) -> SimConfig:
    return SimConfig(architecture="hybrid", cache_capacity=1500,
                     n_shards=2, seed=0, fault_schedule=schedule,
                     trace=trace, **kw)


def _run(cfg, n=400):
    sim = ServingSimulator(PolicyEngine(paper_policies()), cfg)
    return sim.run(scenario_generator("flash_crowd", seed=0), n)


class TestTracingParity:
    def test_tracing_off_and_on_are_counter_identical(self):
        sched = FaultSchedule(shard_outages=[(2.0, 5.0, 0)])
        off = _run(_sim_cfg(False, sched))
        on = _run(_sim_cfg(True, sched))
        assert off.metrics.snapshot() == on.metrics.snapshot()
        assert off.mean_latency_ms == on.mean_latency_ms
        assert off.p95_latency_ms == on.p95_latency_ms
        assert off.fault_stats == on.fault_stats
        assert off.index_sync == on.index_sync
        assert off.trace is None and on.trace is not None

    def test_traced_fault_run_closes_accounting_and_attributes(self):
        sched = FaultSchedule(shard_outages=[(2.0, 6.0, 0)],
                              store_get_failures=FaultSchedule.op_range(
                                  5, 2))
        res = _run(_sim_cfg(True, sched))
        rec = res.trace
        assert check_span_accounting(rec) == []
        assert coverage_fraction(rec) == pytest.approx(1.0)
        per = res.metrics.per_category
        accrued = {}
        for ev in rec.events:
            if ev.name == "degraded_accrue":
                c = ev.fields["category"]
                accrued[c] = accrued.get(c, 0.0) + ev.fields["seconds"]
        for name, st in per.items():
            if st.degraded_seconds > 0:
                assert accrued.get(name, 0.0) == pytest.approx(
                    st.degraded_seconds, rel=1e-9), name
        deg_events = sum(1 for ev in rec.events
                         if ev.name == "degraded_miss")
        assert deg_events == sum(s.degraded_misses for s in per.values())
        assert deg_events > 0

    def test_migration_records_spans_and_closes(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        cache = ShardedSemanticCache(
            _policies(), dim=DIM, capacity=256, n_shards=2, clock=clock,
            seed=0, obs=rec)
        v = _bank(2, 24)
        cache.insert_batch(v, ["a"] * 24, [f"q{i}" for i in range(24)],
                           [f"r{i}" for i in range(24)])
        dst = 1 - cache.shard_of("a")
        cache.migrate_category("a", dst)
        stages = {sp.stage for sp in rec.spans}
        assert "migration" in stages and "migration_copy" in stages
        assert rec.event_counts().get("migration_step", 0) > 0
        assert check_span_accounting(rec) == []


# ------------------------------------------------------------- satellites
class TestMeanLatencyDenominator:
    def test_unit_served_only_denominator(self):
        st = CategoryStats(lookups=10, degraded_misses=4,
                           latency_ms_sum=60.0)
        # 6 served lookups carried the 60ms, not 10
        assert st.mean_latency_ms == pytest.approx(10.0)
        st_all = CategoryStats(lookups=10, latency_ms_sum=60.0)
        assert st_all.mean_latency_ms == pytest.approx(6.0)

    def test_all_degraded_is_zero_not_nan(self):
        st = CategoryStats(lookups=5, degraded_misses=5,
                           latency_ms_sum=0.0)
        assert st.mean_latency_ms == 0.0

    def test_outage_regression_consistent_with_hit_rate(self):
        # same denominator discipline as hit_rate: an outage must not
        # dilute the mean below what the served lookups actually paid
        sched = FaultSchedule(shard_outages=[(1.0, 8.0, 0)])
        res = _run(_sim_cfg(True, sched))
        for st in res.metrics.per_category.values():
            if not st.degraded_misses:
                continue
            served = st.lookups - st.degraded_misses
            assert st.mean_latency_ms == pytest.approx(
                st.latency_ms_sum / served if served else 0.0)


class TestOverallRow:
    def test_registry_snapshot_overall(self):
        reg = MetricsRegistry()
        a, b = reg.cat("a"), reg.cat("b")
        a.lookups, a.hits, a.misses = 10, 4, 6
        b.lookups, b.hits, b.misses, b.degraded_misses = 10, 2, 4, 4
        snap = reg.snapshot()
        ov = snap["_overall"]
        assert ov["lookups"] == 20 and ov["hits"] == 6
        # rate recomputed from summed counters (served = 20 - 4)
        assert ov["hit_rate"] == pytest.approx(6 / 16, abs=1e-4)
        assert ov["availability"] == pytest.approx(1 - 4 / 20, abs=1e-4)
        assert overall_row(reg.per_category) == ov

    def test_sharded_snapshot_overall(self):
        cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=128,
                                     n_shards=2, clock=SimClock(), seed=0)
        v = _bank(3, 16)
        cache.insert_batch(v, ["a"] * 8 + ["b"] * 8,
                           [f"q{i}" for i in range(16)],
                           [f"r{i}" for i in range(16)])
        cache.lookup_batch(v, ["a"] * 8 + ["b"] * 8)
        snap = cache.metrics.snapshot()
        assert snap["_overall"]["lookups"] == \
            snap["a"]["lookups"] + snap["b"]["lookups"]
        assert snap["_overall"]["inserts"] == \
            snap["a"]["inserts"] + snap["b"]["inserts"]


class TestMetricsRoundTrips:
    def test_to_dict_fields_round_trip(self):
        st = CategoryStats(lookups=7, hits=3, misses=4, inserts=5,
                           degraded_misses=0, store_timeouts=1,
                           reranks=2, latency_ms_sum=21.0)
        d = st.to_dict()
        for k in ("lookups", "hits", "misses", "inserts",
                  "store_timeouts", "reranks"):
            assert d[k] == getattr(st, k)
        assert d["hit_rate"] == round(st.hit_rate, 4)
        assert d["mean_latency_ms"] == round(st.mean_latency_ms, 3)
        assert json.loads(json.dumps(d)) == d

    def test_slo_report_shape_and_values(self):
        cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=128,
                                     n_shards=2, clock=SimClock(), seed=0)
        v = _bank(4, 8)
        cache.insert_batch(v, ["a"] * 8, [f"q{i}" for i in range(8)],
                           [f"r{i}" for i in range(8)])
        cache.lookup_batch(v, ["a"] * 8)
        rep = cache.metrics.slo_report()
        assert "a" in rep
        row = rep["a"]
        assert set(row) == {"availability", "lookups", "degraded_misses",
                            "degraded_seconds", "replicas"}
        assert row["availability"] == 1.0
        assert row["lookups"] == 8
        assert row["replicas"] >= 1


# ------------------------------------------------------------------ export
class TestExports:
    def _traced_recorder(self):
        clock = SimClock()
        rec = TraceRecorder(clock)
        with rec.span("lookup", category="a", shard=0):
            with rec.span("search", category="a", shard=0):
                clock.advance(0.002)
        rec.event("eviction", reason="quota")
        return rec

    def test_jsonl_dump_valid_and_counted(self, tmp_path):
        rec = self._traced_recorder()
        path = tmp_path / "trace.jsonl"
        n = rec.to_jsonl(path)
        lines = path.read_text().splitlines()
        assert n == len(lines) == 3              # 2 spans + 1 event
        objs = [json.loads(ln) for ln in lines]
        assert [o["type"] for o in objs] == ["span", "span", "event"]
        assert objs[1]["parent"] == objs[0]["id"]
        assert objs[1]["dur_ms"] == pytest.approx(2.0)

    def test_prometheus_exposition(self):
        rec = self._traced_recorder()
        reg = MetricsRegistry()
        reg.cat("a").lookups = 3
        text = prometheus_text(snapshot=reg.snapshot(), rec=rec)
        assert '# TYPE repro_cache_lookups counter' in text
        assert 'repro_cache_lookups{category="a"} 3' in text
        assert 'repro_cache_lookups{category="_overall"} 3' in text
        assert '# TYPE repro_stage_latency_ms histogram' in text
        assert 'le="+Inf"' in text
        assert 'repro_events_total{name="eviction"} 1' in text
        assert "repro_spans_opened_total 2" in text
        # cumulative bucket counts are monotone per series
        for series in ('stage="search"',):
            cum = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
                   if "_bucket{" in ln and series in ln]
            assert cum == sorted(cum)

    def test_telemetry_report_mentions_stages_and_overall(self):
        rec = self._traced_recorder()
        reg = MetricsRegistry()
        reg.cat("a").lookups = 3
        out = telemetry_report(rec, snapshot=reg.snapshot())
        assert "search" in out and "lookup" in out
        assert "opened=2 closed=2" in out
        assert "eviction" in out
        assert "overall:" in out


# --------------------------------------------------------------- span lint
GOOD_SRC = '''
class C:
    def charged(self):
        with self._span("search"):
            self.clock.advance(0.001)
'''

BAD_SRC = '''
class C:
    def charged(self):
        self.clock.advance(0.001)
'''

PRAGMA_SRC = '''
class C:
    def charged(self):
        self.clock.advance(0.001)  # span-ok: caller-owned span
'''

PRAGMA_ABOVE_SRC = '''
class C:
    def charged(self):
        # span-ok: inter-arrival idle
        self.clock.advance(self.t - self.clock.now())
'''


class TestSpanLint:
    def test_spanned_charge_passes(self):
        assert span_lint.lint_source(GOOD_SRC) == []

    def test_unspanned_charge_flagged(self):
        out = span_lint.lint_source(BAD_SRC, filename="x.py")
        assert len(out) == 1
        assert out[0].rule == "SpanCoverage"
        assert "x.py:charged" in out[0].target

    def test_pragma_on_line_or_above_passes(self):
        assert span_lint.lint_source(PRAGMA_SRC) == []
        assert span_lint.lint_source(PRAGMA_ABOVE_SRC) == []

    def test_recorder_span_call_counts(self):
        src = GOOD_SRC.replace("self._span", "rec.span")
        assert span_lint.lint_source(src) == []

    def test_real_traced_modules_clean(self):
        assert span_lint.lint_paths() == []
