"""Distribution: sharding plans, MoE EP equivalence, multi-device train
step, mesh construction. Multi-device tests run in subprocesses so the
main process keeps its single-CPU jax runtime."""

import json
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.context import Dist
from repro.launch import sharding as shd


def run_sub(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_param_plan_rules_single_pod():
    cfg = get_config("deepseek_67b")

    class FakeDist(Dist):
        pass
    # synthesize a 16×16 dist without devices: mesh=None blocks axis sizes,
    # so exercise through a subprocess for the real thing; here check the
    # structural walk with a 1-device dist (everything replicated).
    dist = Dist.single()
    plan = shd.param_plan(cfg, dist, training=True)
    leaves = []
    def walk(t):
        if isinstance(t, P):
            leaves.append(t)
        elif isinstance(t, dict):
            for v in t.values():
                walk(v)
    walk(plan.params)
    assert len(leaves) > 5


def test_param_plan_on_real_mesh():
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed.context import Dist
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        dist = Dist.from_mesh(mesh)
        cfg = get_config("deepseek_67b")
        plan = shd.param_plan(cfg, dist, training=True)
        s = plan.params["stack"]["sub0"]
        assert s["mix"]["wq"] == P(None, "data", "model", None), s["mix"]["wq"]
        assert s["mlp"]["w_gate"] == P(None, "data", "model")
        assert s["mlp"]["w_down"] == P(None, "model", "data")
        assert plan.params["embed"] == P("model", "data")
        # serving: no fsdp
        plan_s = shd.param_plan(cfg, dist, training=False)
        assert plan_s.params["stack"]["sub0"]["mlp"]["w_gate"] == P(None, None, "model")
        # gemma2: 8 heads don't divide model=2? they do; use granite_moe 24 H % 2 == 0
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_matches_dense_on_mesh():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.context import Dist
        from repro.models import moe as moe_mod
        from repro.models.config import ArchConfig
        from repro.models.layers import init_moe
        cfg = ArchConfig(name="t", family="moe", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                         n_experts=10, moe_top_k=3, d_ff_expert=32,
                         capacity_factor=4.0)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        dist = Dist.from_mesh(mesh)
        p = init_moe(jax.random.key(0), cfg)
        x = jnp.asarray(np.random.default_rng(3).standard_normal((64, 64)),
                        jnp.float32)
        y_ep, aux_ep = jax.jit(
            lambda x, p: moe_mod.moe_ffn_ep(x, p, cfg, dist))(x, p)
        y_ref, aux_ref = moe_mod.moe_ffn_dense_exact(x, p, cfg)
        err = float(jnp.max(jnp.abs(y_ep - y_ref)))
        assert err < 1e-4, err
        assert abs(float(aux_ep) - float(aux_ref)) < 1e-5
        print("OK", err)
    """)
    assert "OK" in out


def test_train_step_runs_sharded():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed.context import Dist
        from repro.launch import sharding as shd
        from repro.launch.steps import make_train_step
        from repro.models.model import Model
        from repro.optim.adamw import AdamWConfig, init_opt_state

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        dist = Dist.from_mesh(mesh)
        cfg = get_config("granite_moe_3b_a800m").reduced(grad_accum=2)
        model = Model(cfg, dist)
        params = model.init_params(jax.random.key(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        plan = shd.param_plan(cfg, dist, training=True)
        pshard = plan.shardings(mesh)
        params = jax.device_put(params, pshard)
        step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 64))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)))}
        p2, o2, met = step(params, opt, batch)
        loss1 = float(met["loss"])
        p3, o3, met = step(p2, o2, batch)
        loss2 = float(met["loss"])
        assert np.isfinite(loss1) and np.isfinite(loss2)
        assert loss2 < loss1 + 0.1  # moving
        print("OK", loss1, loss2)
    """)
    assert "OK" in out


def test_production_mesh_shapes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}, m1.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """, devices=512)
    assert "OK" in out


def test_dryrun_results_complete():
    """The committed dry-run results must cover all 64 runnable compiles."""
    import glob
    import os
    root = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "results", "dryrun")
    files = glob.glob(os.path.join(root, "*.json"))
    if not files:
        pytest.skip("dry-run results not generated yet")
    assert len(files) >= 64
    for f in files[:4]:
        with open(f) as fh:
            payload = json.load(fh)
        assert payload["cost_analysis"].get("flops", 0) > 0


def test_sharded_loss_equals_single_device():
    """End-to-end numerical equivalence: the mesh run (EP MoE + sequence-
    sharded attention + all sharding constraints) must produce the same
    loss as the single-device run up to bf16 reduction noise."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.context import Dist
        from repro.launch import sharding as shd
        from repro.models.model import Model

        cfg = get_config("granite_moe_3b_a800m").reduced(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            vocab_size=256, n_experts=8, moe_top_k=2, d_ff_expert=32,
            capacity_factor=4.0)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(1, 256, (8, 64))),
                 "labels": jnp.asarray(rng.integers(0, 256, (8, 64)))}

        m_single = Model(cfg, None)
        params = m_single.init_params(jax.random.key(0))
        loss_single, _ = jax.jit(m_single.loss_fn)(params, batch)

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        dist = Dist.from_mesh(mesh)
        m_mesh = Model(cfg, dist)
        pshard = shd.param_plan(cfg, dist, training=True).shardings(mesh)
        params_sharded = jax.device_put(params, pshard)
        loss_mesh, _ = jax.jit(m_mesh.loss_fn)(params_sharded, batch)

        d = abs(float(loss_single) - float(loss_mesh))
        assert d < 5e-3, (float(loss_single), float(loss_mesh))
        print("OK", float(loss_single), float(loss_mesh))
    """)
    assert "OK" in out


def test_pipeline_over_pod_matches_sequential():
    """GPipe-over-pod (GSPMD roll schedule) must equal the sequential
    stack's loss exactly — same math, different schedule."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.context import Dist
        from repro.launch import sharding as shd
        from repro.launch.pipeline import (make_pp_loss, pp_stack_specs,
                                           reshape_stack_for_pp)
        from repro.models.model import Model

        cfg = get_config("llama3_2_3b").reduced(n_layers=4, d_model=64,
                                                n_heads=4, n_kv_heads=2,
                                                head_dim=16, vocab_size=256)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(1, 256, (8, 32))),
                 "labels": jnp.asarray(rng.integers(0, 256, (8, 32)))}

        m0 = Model(cfg, None)
        params = m0.init_params(jax.random.key(0))
        loss_seq, _ = jax.jit(m0.loss_fn)(params, batch)

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        dist = Dist.from_mesh(mesh)
        m = Model(cfg, dist)
        pp_params = dict(params)
        pp_params["stack"] = reshape_stack_for_pp(params["stack"], 2)
        loss_fn = make_pp_loss(m, n_micro=4)
        loss_pp, _ = jax.jit(loss_fn)(pp_params, batch)
        d = abs(float(loss_seq) - float(loss_pp))
        assert d < 2e-3, (float(loss_seq), float(loss_pp))
        print("OK", float(loss_seq), float(loss_pp))
    """)
    assert "OK" in out
