"""Fault injection & degraded-mode serving (core/faults.py + the
degraded paths in core/{shard,cache,storage}.py).

Three property families pin the tentpole's guarantees:

* **Inertness** — an absent or empty-schedule injector leaves every
  hook a no-op: the wired stack's observable trace is bit-identical to
  the unwired one (the bench_faults baseline gate, in miniature).
* **Degraded accounting** — outage-window lookups resolve as counted
  ``degraded_miss``es with ``hits + misses + degraded == lookups`` in
  every run, and acknowledged writes queued during the outage ALL
  land after recovery (zero acknowledged-write loss).
* **Crash-safe migration** — an injected crash at EVERY enumerable
  protocol step index, across {1,2,4} shards × {flat,hnsw} ×
  {fp32,int8}, leaves exactly one authoritative owner, and
  resume-or-abort recovery loses no acknowledged write (fenced
  cutover-window writes included).
"""

import numpy as np
import pytest

from repro.core import (FaultInjector, FaultSchedule, InjectedCrash,
                        SemanticCache, ShardedSemanticCache, SimClock,
                        StoreTimeout, TransientStoreError)
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.core.storage import (Document, FlakyStore, InMemoryStore,
                                RetryingStore)

DIM = 48


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=1e6, quota=0.40),
        CategoryConfig("b", threshold=0.78, ttl=1e6, quota=0.40),
        CategoryConfig("d", threshold=0.95, ttl=1.0, quota=0.0,
                       allow_caching=False),
    ])


def _bank(cat: str, n: int = 64) -> np.ndarray:
    rng = np.random.default_rng({"a": 100, "b": 101, "d": 102}[cat])
    v = rng.standard_normal((n, DIM)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _sharded(n_shards=2, faults=None, index_kind="flat",
             emb_dtype="float32", clock=None, **kw):
    return ShardedSemanticCache(
        _policies(), dim=DIM, capacity=256, n_shards=n_shards,
        clock=clock or SimClock(), index_kind=index_kind,
        emb_dtype=emb_dtype, seed=0, faults=faults, **kw)


# ---------------------------------------------------------------- injector
class TestFaultInjector:
    def test_empty_schedule_is_inert(self):
        inj = FaultInjector()
        assert not inj.active
        assert not inj.shard_down(0)
        inj.store_op("get")
        inj.crash_point("migration")
        # inert injectors count NOTHING — the hooks are true no-ops
        assert inj.stats()["store_ops"] == {"get": 0, "put": 0, "delete": 0}
        assert inj.visits("migration") == 0

    def test_outage_window_is_clock_driven(self):
        clk = SimClock()
        inj = FaultInjector(FaultSchedule(shard_outages=[(1.0, 2.0, 1)]),
                            clk)
        assert not inj.shard_down(1)        # t=0: before the window
        clk.advance(1.5)
        assert inj.shard_down(1)
        assert not inj.shard_down(0)        # other shards unaffected
        clk.advance(1.0)
        assert not inj.shard_down(1)        # t=2.5: window closed

    def test_store_op_indices_fire_once_each(self):
        sched = FaultSchedule(store_get_failures=FaultSchedule.op_range(1, 2))
        inj = FaultInjector(sched)
        inj.store_op("get")                             # op 0: fine
        for _ in range(2):                              # ops 1, 2: scheduled
            with pytest.raises(TransientStoreError):
                inj.store_op("get")
        inj.store_op("get")                             # op 3: fine again
        inj.store_op("put")                             # other kinds untouched
        assert inj.injected["store_faults"] == 2

    def test_crash_point_fires_once_then_disarms(self):
        inj = FaultInjector(FaultSchedule(crash_at={"site": 2}))
        inj.crash_point("site")
        inj.crash_point("site")
        with pytest.raises(InjectedCrash) as e:
            inj.crash_point("site")
        assert e.value.visit == 2
        # recovery re-traverses the same site without re-crashing
        inj.crash_point("site")
        assert inj.visits("site") == 4
        assert inj.injected["crashes"] == 1


# ------------------------------------------------------------ retry wrapper
class TestRetryingStore:
    def _stack(self, get_failures=(), retries=3, backoff_ms=1.0,
               budget_ms=50.0):
        clk = SimClock()
        inj = FaultInjector(
            FaultSchedule(store_get_failures=frozenset(get_failures)), clk)
        store = RetryingStore(FlakyStore(InMemoryStore(), inj), clock=clk,
                              retries=retries, backoff_ms=backoff_ms,
                              budget_ms=budget_ms)
        return store, clk

    def test_absorbs_bounded_run_with_deterministic_backoff(self):
        store, clk = self._stack(get_failures={0, 1})
        store.put(Document(7, "q", "r", 0.0, "a"))
        doc = store.get(7)                  # ops 0,1 fail; op 2 succeeds
        assert doc is not None and doc.response == "r"
        # backoff ladder 1ms·2^0 + 1ms·2^1 charged to the sim clock
        assert clk.now() == pytest.approx(0.003)
        assert store.stats["get_retries"] == 2
        assert store.stats["get_timeouts"] == 0

    def test_retry_exhaustion_raises_store_timeout(self):
        store, _ = self._stack(get_failures=set(range(10)), retries=2)
        store.put(Document(7, "q", "r", 0.0, "a"))
        with pytest.raises(StoreTimeout):
            store.get(7)
        assert store.stats["get_timeouts"] == 1

    def test_latency_budget_caps_backoff_spend(self):
        # generous retry count, tiny budget: the cumulative-backoff
        # guard must break the loop long before 50 attempts
        store, clk = self._stack(get_failures=set(range(60)), retries=50,
                                 backoff_ms=4.0, budget_ms=10.0)
        store.put(Document(7, "q", "r", 0.0, "a"))
        with pytest.raises(StoreTimeout):
            store.get(7)
        assert clk.now() * 1e3 <= 10.0 + 1e-9

    def test_store_timeout_degrades_hit_not_raises(self):
        """A would-be cache hit whose doc fetch exhausts the retry
        budget serves as a counted store_timeout miss; the entry stays
        resident and hits again once the store heals."""
        clk = SimClock()
        # gets 0-2 fail: the first lookup's fetch burns all 3 attempts
        # (retries=2) and times out; the second lookup (get op 3) heals
        inj = FaultInjector(FaultSchedule(
            store_get_failures=FaultSchedule.op_range(0, 3)), clk)
        cache = SemanticCache(
            _policies(), dim=DIM, capacity=64, clock=clk, index_kind="flat",
            store=RetryingStore(FlakyStore(InMemoryStore(), inj), clock=clk,
                                retries=2))
        emb = _bank("a")[0]
        cache.insert(emb, "a", "q", "r")
        res = cache.lookup(emb, "a")
        assert not res.hit and res.reason == "store_timeout"
        st = cache.metrics.cat("a")
        assert (st.store_timeouts, st.hits, st.misses) == (1, 0, 1)
        res = cache.lookup(emb, "a")        # fault run consumed: hit again
        assert res.hit and res.response == "r"
        assert st.hits == 1


# --------------------------------------------------------- degraded serving
class TestDegradedServing:
    def test_outage_lookups_degrade_and_writes_replay(self):
        clk = SimClock()
        inj = FaultInjector(FaultSchedule(shard_outages=[(0.0, 5.0, 0)]),
                            clk)
        cache = _sharded(faults=inj, clock=clk)
        down = [c for c in ("a", "b") if cache.shard_of(c) == 0]
        up = [c for c in ("a", "b") if cache.shard_of(c) == 1]
        assert down and up      # the planner split the two categories
        bank_dn, bank_up = _bank(down[0]), _bank(up[0])
        embs = np.concatenate([bank_dn[:4], bank_up[:4]])
        cats = [down[0]] * 4 + [up[0]] * 4
        reqs = [f"q{i}" for i in range(8)]
        resp = [f"r{i}" for i in range(8)]
        slots = cache.insert_batch(embs, cats, reqs, resp)
        # down-shard writes acknowledged without a slot, queued
        assert all(s < 0 for s in slots[:4]) and all(s >= 0 for s in slots[4:])
        assert cache.wb_pending == 4
        res = cache.lookup_batch(embs, cats)
        assert [r.reason for r in res[:4]] == ["degraded"] * 4
        assert all(r.hit for r in res[4:])  # the up shard is unaffected
        st = cache.metrics.cat(down[0])
        assert st.degraded_misses == 4 and st.lookups == 4
        # the accounting invariant bench_faults gates on
        assert st.hits + st.misses + st.degraded_misses == st.lookups
        assert st.hit_rate == 0.0 and st.availability == 0.0
        # recovery: the next front-door op replays the queue FIFO
        clk.advance(10.0)
        res = cache.lookup_batch(embs, cats)
        assert all(r.hit for r in res)      # zero acknowledged-write loss
        assert cache.wb_pending == 0
        assert cache.fault_stats["wb_replayed"] == 4
        assert cache.metrics.cat(down[0]).availability > 0.0

    def test_compliance_classification_survives_outage(self):
        clk = SimClock()
        inj = FaultInjector(FaultSchedule(shard_outages=[(0.0, 5.0, 0),
                                                         (0.0, 5.0, 1)]),
                            clk)
        cache = _sharded(faults=inj, clock=clk)
        res = cache.lookup(_bank("d")[0], "d")
        assert res.reason == "compliance"   # policy-side, needs no index
        st = cache.metrics.cat("d")
        assert st.degraded_misses == 0 and st.compliance_rejects == 1

    def test_write_behind_queue_is_bounded(self):
        clk = SimClock()
        inj = FaultInjector(FaultSchedule(shard_outages=[(0.0, 5.0, 0),
                                                         (0.0, 5.0, 1)]),
                            clk)
        cache = _sharded(faults=inj, clock=clk, write_behind_capacity=3)
        bank = _bank("a")
        slots = cache.insert_batch(bank[:5], ["a"] * 5,
                                   [f"q{i}" for i in range(5)],
                                   [f"r{i}" for i in range(5)])
        assert all(s < 0 for s in slots)
        assert cache.wb_pending == 3        # overflow dropped, not queued
        assert cache.fault_stats["wb_dropped"] == 2
        clk.advance(10.0)
        res = cache.lookup_batch(bank[:5], ["a"] * 5)
        # exactly the acknowledged (enqueued) writes survive
        assert sum(r.hit for r in res) == 3 and cache.wb_pending == 0

    def test_empty_schedule_bit_identical_to_no_injector(self):
        """The inertness property: wiring an injector with an EMPTY
        schedule changes nothing observable — trace, counters, clock."""
        def run(faults):
            clk = SimClock()
            cache = _sharded(faults=faults, clock=clk, index_kind="hnsw")
            bank_a, bank_b = _bank("a"), _bank("b")
            trace = []
            for r in range(6):
                embs = np.concatenate([bank_a[r:r + 3], bank_b[r:r + 3]])
                cats = ["a"] * 3 + ["b"] * 3
                res = cache.lookup_batch(embs, cats)
                trace.append([(x.hit, x.reason, x.response) for x in res])
                miss = [i for i, x in enumerate(res) if not x.hit]
                if miss:
                    cache.insert_batch(embs[miss], [cats[i] for i in miss],
                                       [f"q{r}.{i}" for i in miss],
                                       [f"r{r}.{i}" for i in miss])
                clk.advance(1.0)
            return trace, cache.metrics.snapshot(), clk.now()
        base = run(None)
        wired = run(FaultInjector(FaultSchedule()))
        assert wired == base


# ------------------------------------------------------ crash-safe cutover
def _seed_category(cache, cat: str, n: int = 12) -> np.ndarray:
    bank = _bank(cat)[:n]
    cache.insert_batch(bank, [cat] * n, [f"q{i}" for i in range(n)],
                       [f"r{i}" for i in range(n)])
    return bank


def _migration_visits(n_shards, index_kind, emb_dtype) -> int:
    """Dry-run the migration under an armed-but-never-firing injector to
    measure the enumerable crash-index space."""
    inj = FaultInjector(FaultSchedule(crash_at={"elsewhere": 0}))
    cache = _sharded(n_shards=n_shards, faults=inj, index_kind=index_kind,
                     emb_dtype=emb_dtype)
    _seed_category(cache, "a", 12)
    src = cache.shard_of("a")
    dst = (src + 1) % n_shards
    mig = cache.migrate_category("a", dst, batch_size=4)
    assert mig.done and mig.journal[-1] == "unfence"
    return inj.visits("migration")


@pytest.mark.parametrize("index_kind,emb_dtype", [
    ("flat", "float32"), ("flat", "int8"),
    ("hnsw", "float32"), ("hnsw", "int8"),
])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_migration_crash_at_every_step(n_shards, index_kind, emb_dtype):
    """THE tentpole property: for every enumerable crash index k in the
    migration protocol, an injected crash at k followed by recovery
    leaves exactly one authoritative owner holding ALL acknowledged
    entries — resume lands them on the target, abort (pre-flip only)
    back on the source, and post-flip recovery always finishes."""
    if n_shards == 1:
        # degenerate row of the matrix: there is nowhere to migrate to,
        # and migrate_category refuses rather than stranding anything
        cache = _sharded(n_shards=1)
        _seed_category(cache, "a", 12)
        assert cache.migrate_category("a", 0) is None
        assert cache.migrate_category("a", 3) is None
        assert cache.category_count("a") == 12
        return
    n_steps = _migration_visits(n_shards, index_kind, emb_dtype)
    assert n_steps >= 9     # drain batches + 2 per batch + cutover fences
    for k in range(n_steps):
        for mode in ("resume", "abort"):
            inj = FaultInjector(FaultSchedule(crash_at={"migration": k}))
            cache = _sharded(n_shards=n_shards, faults=inj,
                             index_kind=index_kind, emb_dtype=emb_dtype)
            bank = _seed_category(cache, "a", 12)
            src = cache.shard_of("a")
            dst = (src + 1) % n_shards
            with pytest.raises(InjectedCrash):
                cache.migrate_category("a", dst, batch_size=4)
            mig = cache._migrations.get("a")
            assert mig is not None and not mig.done
            # authority is already unambiguous BEFORE recovery runs
            assert cache.shard_of("a") in (src, dst)
            action = mig.recover(mode)
            owner = cache.shard_of("a")
            if action == "aborted":
                assert owner == src and not mig.flipped
            else:
                assert owner == dst
            # exactly one owner, holding every acknowledged entry
            counts = [cache.shards[s].category_count("a")
                      for s in range(n_shards)]
            assert counts[owner] == 12
            assert sum(counts) == 12
            res = cache.lookup_batch(bank, ["a"] * 12)
            assert all(r.hit for r in res), (k, mode)
            assert "a" not in cache._migrations


def test_fenced_writes_replay_to_recovered_owner():
    """A write arriving while a crashed cutover holds the fence is
    acknowledged into the fence queue and must surface on whichever
    shard recovery makes authoritative — for both recovery modes."""
    def crash_at(k):
        inj = FaultInjector(FaultSchedule(crash_at={"migration": k}))
        cache = _sharded(n_shards=2, faults=inj)
        bank = _seed_category(cache, "a", 12)
        src = cache.shard_of("a")
        with pytest.raises(InjectedCrash):
            cache.migrate_category("a", 1 - src, batch_size=4)
        return cache, bank, src, cache._migrations["a"]

    # find a crash index inside the fenced pre-flip window
    fenced_k = next(k for k in range(_migration_visits(2, "flat", "float32"))
                    if (lambda m: m.fenced and not m.flipped)(crash_at(k)[3]))
    for mode, expect_flip in (("resume", True), ("abort", False)):
        cache, bank, src, mig = crash_at(fenced_k)
        assert mig.fenced and not mig.flipped
        late = _bank("a")[20]
        slot = cache.insert(late, "a", "late-q", "late-r")
        assert slot < 0 and len(mig.fence_queue) == 1
        assert cache.fault_stats["fenced_writes"] == 1
        mig.recover(mode)
        assert cache.fault_stats["fence_replayed"] == 1
        res = cache.lookup(late, "a")
        assert res.hit and res.response == "late-r"
        assert cache.shard_of("a") == ((1 - src) if expect_flip else src)
        # the original 12 acknowledged writes also all survived
        assert all(r.hit for r in cache.lookup_batch(bank, ["a"] * 12))


def test_migration_without_faults_unchanged():
    """No injector → the journaled cutover is pure bookkeeping: same
    outcome as the pre-crash-safety protocol (moved count, owner flip,
    admission-state handoff, empty fence)."""
    cache = _sharded(n_shards=2)
    bank = _seed_category(cache, "a", 12)
    src = cache.shard_of("a")
    mig = cache.migrate_category("a", 1 - src, batch_size=5)
    assert mig.done and mig.moved == 12
    assert mig.journal == ["fence", "catchup", "reconcile", "flip",
                           "purge", "unfence"]
    assert not mig.fenced and not mig.fence_queue
    assert cache.shard_of("a") == 1 - src
    assert all(r.hit for r in cache.lookup_batch(bank, ["a"] * 12))
