"""Static contract checker (ISSUE 6): the checker itself is under test.

Two suites. NO-FALSE-NEGATIVE: a synthetic violation per rule — a
gather-materializing search, a host callback, an un-donated scatter, a
full-table int8→fp32 rematerialization, a per-batch-size compile blowup,
a mirror write with no dirty marking, an oversized BlockSpec — each of
which the intended rule MUST flag, and (for the HLO rules, which share
targets) no *other* rule may flag. NO-FALSE-POSITIVE: every real hot
path — both index kinds, both resident dtypes, the delta-flush
scatters, the sharded serve sweep, the production kernel shape sweep,
the real core modules — comes back clean. Everything here is static
(lower/parse/AST): zero wall-clock-dependent assertions.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import hlo_cost, mirror_lint, vmem
from repro.analysis.contracts import (CompileCensus, DonationHonored,
                                      DtypeDiscipline, HloTrace,
                                      NoHostTransfer, build_index,
                                      collect_compile_census,
                                      collect_hot_path_traces,
                                      lower_delta_flush, run_rules)

D = 384


def _unit(rng, n, d=D):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _trace(fn, *args, meta, name="synthetic") -> HloTrace:
    lowered = jax.jit(fn).lower(*args)
    return HloTrace(name=name, hlo=lowered.compile().as_text(),
                    stablehlo=lowered.as_text(), meta=meta)


def _rule_names(violations) -> set:
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# No-false-negative: each synthetic violation trips exactly its rule.
# ---------------------------------------------------------------------------

def test_flags_materialized_gather_and_only_that():
    """A search that expands candidates through a (B, K, d) XLA gather —
    the exact shape the fused hop exists to avoid."""
    rng = np.random.default_rng(0)
    emb = jnp.asarray(_unit(rng, 64))
    idx = jnp.asarray(rng.integers(0, 64, (8, 16)), jnp.int32)
    q = jnp.asarray(_unit(rng, 8))

    def bad_search(emb, idx, q):
        rows = emb[idx]                          # (B, K, d) materialized
        return jnp.einsum("bkd,bd->bk", rows, q)

    t = _trace(bad_search, emb, idx, q, meta={"d": D})
    viols = run_rules([t])
    assert _rule_names(viols) == {"NoMaterializedGather"}
    assert "gather" in viols[0].message


def test_flags_host_callback_and_only_that():
    """A host callback spliced into a 'hot path' executable."""
    def bad(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2.0,
            jax.ShapeDtypeStruct((8,), jnp.float32), x)
        return y + 1.0

    t = _trace(bad, jnp.zeros(8, jnp.float32), meta={"d": D})
    viols = run_rules([t])
    assert _rule_names(viols) == {"NoHostTransfer"}
    assert "callback" in viols[0].message


def test_topk_custom_call_is_whitelisted():
    """CPU TopK lowers to a custom-call; it is NOT a host transfer."""
    t = _trace(lambda x: jax.lax.top_k(x, 4)[0],
               jnp.zeros((8, 64), jnp.float32), meta={"d": D})
    assert NoHostTransfer().check(t) == []


def test_flags_undonated_scatter_and_only_that():
    """The delta-flush scatter with donation dropped: functionally
    identical, but every sync now copies the whole table."""
    table = jax.ShapeDtypeStruct((256, D), jnp.float32)
    rows = jax.ShapeDtypeStruct((8,), jnp.int32)
    vals = jax.ShapeDtypeStruct((8, D), jnp.float32)
    t = _trace(lambda t, r, v: t.at[r].set(v), table, rows, vals,
               meta={"d": D, "capacity": 256, "donated_args": (0,)})
    viols = run_rules([t])
    assert _rule_names(viols) == {"DonationHonored"}
    assert "argument 0" in viols[0].message


def test_flags_fp32_rematerialization_and_only_that():
    """A quantized 'search' that converts the whole int8 table to fp32
    before the dot — the silent 4x HBM regression DtypeDiscipline pins."""
    cap = 4096
    emb_q = jnp.zeros((cap, D), jnp.int8)
    scale = jnp.ones((cap,), jnp.float32)
    q = jnp.zeros((8, D), jnp.float32)

    def bad_quant_search(emb_q, scale, q):
        table = emb_q.astype(jnp.float32) * scale[:, None]  # full fp32 copy
        return q @ table.T

    t = _trace(bad_quant_search, emb_q, scale, q,
               meta={"d": D, "capacity": cap, "emb_dtype": "int8"})
    viols = run_rules([t])
    assert _rule_names(viols) == {"DtypeDiscipline"}
    assert any("materialization" in v.message for v in viols)


def test_flags_quantized_trace_with_no_s8_traffic():
    """A trace claiming int8 residency that never touches s8 bytes: the
    fp32 control-plane table leaked onto the hot path."""
    q = jnp.zeros((8, D), jnp.float32)
    emb = jnp.zeros((4096, D), jnp.float32)
    t = _trace(lambda e, q: q @ e.T, emb, q,
               meta={"d": D, "capacity": 4096, "emb_dtype": "int8"})
    viols = DtypeDiscipline().check(t)
    assert len(viols) == 1 and "zero s8 bytes" in viols[0].message


def test_flags_per_batch_compile_blowup():
    """Bucketing regressed: one compiled program per batch size."""
    census = CompileCensus(name="sweep",
                           families={"FlatIndex[float32] shard0": 5,
                                     "FlatIndex[float32] shard1": 1})
    viols = run_rules([census])
    assert _rule_names(viols) == {"CompileBudget"}
    assert len(viols) == 1 and "shard0" in viols[0].message


def test_flags_mirror_write_without_dirty_marking():
    """A host-table write whose rows never reach the dirty log."""
    src = '''
class Index:
    def evict(self, slot):
        self.valid[slot] = False
        self.category[slot] = -1

    def good_evict(self, slot):
        self.valid[slot] = False
        self._dirty.add(slot)
'''
    viols = mirror_lint.lint_source(src, filename="synthetic.py")
    assert len(viols) == 1
    assert viols[0].target.endswith(":evict")
    assert "'category'" in viols[0].message and "'valid'" in viols[0].message


def test_mirror_lint_pragma_and_delegate_are_honored():
    src = '''
def quantize(self, slot, q):
    self.emb_q[slot] = q    # mirror-ok

def insert(self, vec):
    self.slot_inserted[3] = 1.0
    self.index.add_batch(vec)
'''
    assert mirror_lint.lint_source(src) == []


def test_flags_oversized_blockspec():
    """A flat_topk tile fattened past VMEM: 32768 x 384 fp32 x 2
    (double-buffered) = 96 MiB >> 16 MiB. Static estimate, no device."""
    from repro.kernels import flat_topk as FT
    N = 32768
    thunk = lambda: FT.flat_topk(
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((N,), jnp.int8),
        jax.ShapeDtypeStruct((8, D), jnp.float32), block_n=N)
    (fp,) = vmem.estimate(thunk)
    viols = fp.violations("oversized")
    assert len(viols) == 1 and "VMEM" in viols[0].message
    assert fp.vmem_bytes > vmem.VMEM_BYTES


# ---------------------------------------------------------------------------
# No-false-positive: every real hot path is clean.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,dtype", [
    ("flat", "float32"), ("flat", "int8"),
    ("hnsw", "float32"), ("hnsw", "int8"),
])
def test_real_hot_paths_clean(kind, dtype):
    traces = collect_hot_path_traces(kind, dtype)
    assert len(traces) == 3            # search + both flush scatters
    assert run_rules(traces) == []


def test_real_delta_flush_is_donated():
    """Positive control for DonationHonored: the real scatters carry the
    alias attribute the synthetic fixture lacks."""
    idx = build_index("flat", "float32", capacity=256)
    for t in lower_delta_flush(idx):
        assert t.meta["donated_args"] == (0,)
        assert DonationHonored().check(t) == []


def test_real_serve_sweep_compiles_once_per_shard():
    from repro.core.policy import CategoryConfig, PolicyEngine
    from repro.core.shard import ShardedSemanticCache
    pol = PolicyEngine([
        CategoryConfig("a", threshold=0.85, ttl=1e6, quota=0.5),
        CategoryConfig("b", threshold=0.80, ttl=1e6, quota=0.5),
    ])
    cache = ShardedSemanticCache(pol, dim=48, capacity=64, n_shards=2,
                                 index_kind="flat", use_device=True, seed=0)
    rng = np.random.default_rng(0)
    cache.insert_batch(_unit(rng, 6, 48), ["a", "b"] * 3,
                       [f"q{i}" for i in range(6)],
                       [f"r{i}" for i in range(6)])
    census = collect_compile_census(cache, batches=(1, 2, 3, 5, 8))
    assert len(census.families) == 2
    assert run_rules([census]) == []


def test_production_kernel_sweep_fits_budget():
    viols, report = vmem.check_kernels()
    assert viols == []
    assert len(report) >= 24           # all kernels x dtypes x shapes
    names = {fp.name for _, fp in report}
    assert {"_flat_topk_kernel", "_frontier_hop_kernel",
            "_scatter_rows_kernel"} <= names


def test_real_core_modules_pass_mirror_lint():
    assert mirror_lint.lint_paths() == []


# ---------------------------------------------------------------------------
# Shared accounting: hlo_cost's per-dtype byte split (satellite 2).
# ---------------------------------------------------------------------------

def test_bytes_by_dtype_partitions_total_bytes():
    for kind, dtype in (("flat", "int8"), ("hnsw", "float32")):
        trace = collect_hot_path_traces(kind, dtype)[0]
        t = hlo_cost.analyze(trace.hlo)
        assert t.bytes > 0
        assert sum(t.bytes_by_dtype.values()) == pytest.approx(t.bytes)


def test_quantized_trace_moves_mostly_s8_table_bytes():
    """The int8 search's table traffic shows up in the s8 bucket — the
    same accounting path bench_quant's byte gate reads."""
    fp32 = hlo_cost.analyze(
        collect_hot_path_traces("flat", "float32")[0].hlo).bytes_by_dtype
    int8 = hlo_cost.analyze(
        collect_hot_path_traces("flat", "int8")[0].hlo).bytes_by_dtype
    assert fp32.get("s8", 0) < int8["s8"]
    assert int8["s8"] > int8.get("f32", 0) * 0.5   # table dominates
    assert int8.get("f32", 1e18) < fp32["f32"]     # fp32 traffic shrank
